//! detlint CLI.
//!
//! ```text
//! cargo run -p detlint -- check [ROOT] [--format text|json]
//! cargo run -p detlint -- rules
//! cargo run -p detlint -- explain DET002
//! ```
//!
//! `check` exits 0 on a clean tree, 1 when diagnostics survive, 2 on
//! usage or I/O errors. With no ROOT argument it scans `src/` when
//! invoked from the workspace root (`rust/`) and falls back to
//! `rust/src/` when invoked from the repository root.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use detlint::{lint_tree, render_json, render_text, rule, RULES};

const USAGE: &str = "\
detlint — determinism & wire-honesty static analysis for the fed3sfc tree

USAGE:
    detlint check [ROOT] [--format text|json]   lint every *.rs under ROOT
    detlint rules                               list the rule index
    detlint explain <CODE>                      long-form rationale for one rule

Suppression: `// detlint: allow(<RULE>[, <RULE>]) -- <reason>` on the
finding's line (trailing) or the line directly above (own line). The
reason is mandatory; stale or malformed pragmas are DET000 errors.

`check` exits 0 when clean, 1 when diagnostics survive, 2 on usage/I/O
errors.";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        None | Some("--help") | Some("-h") | Some("help") => {
            println!("{USAGE}");
            ExitCode::SUCCESS
        }
        Some("rules") => {
            for r in RULES {
                println!("{}  {}", r.code, r.summary);
            }
            ExitCode::SUCCESS
        }
        Some("explain") | Some("--explain") => match args.get(1).map(|c| (c, rule(c))) {
            Some((code, Some(r))) => {
                println!("{}: {}\n", code, r.summary);
                println!("{}", r.explain);
                ExitCode::SUCCESS
            }
            Some((code, None)) => {
                eprintln!("detlint: unknown rule `{code}` (try `detlint rules`)");
                ExitCode::from(2)
            }
            None => {
                eprintln!("detlint: `explain` needs a rule code (try `detlint rules`)");
                ExitCode::from(2)
            }
        },
        Some("check") => check(&args[1..]),
        Some(other) => {
            eprintln!("detlint: unknown command `{other}`\n\n{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn check(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut format = "text".to_string();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--format" => match it.next() {
                Some(f) if f == "text" || f == "json" => format = f.clone(),
                Some(f) => {
                    eprintln!("detlint: unknown format `{f}` (expected `text` or `json`)");
                    return ExitCode::from(2);
                }
                None => {
                    eprintln!("detlint: `--format` needs a value (`text` or `json`)");
                    return ExitCode::from(2);
                }
            },
            flag if flag.starts_with('-') => {
                eprintln!("detlint: unknown flag `{flag}`\n\n{USAGE}");
                return ExitCode::from(2);
            }
            path if root.is_none() => root = Some(PathBuf::from(path)),
            extra => {
                eprintln!("detlint: unexpected argument `{extra}`");
                return ExitCode::from(2);
            }
        }
    }
    let root = root.unwrap_or_else(|| {
        // From the workspace root (rust/) the tree is ./src; from the
        // repository root it is rust/src.
        if Path::new("src").is_dir() && Path::new("Cargo.toml").is_file() {
            PathBuf::from("src")
        } else {
            PathBuf::from("rust/src")
        }
    });
    if !root.is_dir() {
        eprintln!("detlint: scan root `{}` is not a directory", root.display());
        return ExitCode::from(2);
    }

    let result = match lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: failed to read `{}`: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let prefix = root.to_string_lossy().replace('\\', "/");
    match format.as_str() {
        "json" => print!("{}", render_json(&result, &prefix)),
        _ => {
            print!("{}", render_text(&result.diagnostics, &prefix));
            if result.diagnostics.is_empty() {
                println!(
                    "detlint: clean — {} files checked, {} finding(s) suppressed by pragma",
                    result.files, result.suppressed
                );
            } else {
                println!(
                    "detlint: {} error(s) across {} files ({} finding(s) suppressed by pragma)",
                    result.diagnostics.len(),
                    result.files,
                    result.suppressed
                );
            }
        }
    }
    if result.diagnostics.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
