//! detlint — determinism & wire-honesty static analysis for the fed3sfc
//! source tree.
//!
//! The library half exists so the fixture golden tests and the repo's
//! self-check integration test (`rust/tests/detlint_test.rs`) can lint
//! in-memory sources and real trees without shelling out to the binary.
//!
//! Entry points:
//! - [`lint_files`] — lint a corpus of `(relative_path, source)` pairs
//!   (DET004 duplicate-tag detection is cross-file, so corpora lint as
//!   one unit);
//! - [`lint_source`] — convenience wrapper for a single in-memory file;
//! - [`lint_tree`] — recursively lint every `*.rs` under a root, in
//!   sorted path order;
//! - [`render_text`] / [`render_json`] — ruff-style and machine-readable
//!   rendering of the diagnostics.

mod lexer;
mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{lint_files, rule, Diagnostic, LintResult, Rule, RULES};

/// Lint a single in-memory file under a virtual relative path (rules are
/// path-sensitive: e.g. DET002 only fires under `coordinator/`,
/// `compress/`, `simnet/`).
pub fn lint_source(rel: &str, src: &str) -> LintResult {
    lint_files(&[(rel.to_string(), src.to_string())])
}

/// Recursively collect every `*.rs` file under `root` (sorted by relative
/// path, `/`-separated on every platform) and lint them as one corpus.
pub fn lint_tree(root: &Path) -> io::Result<LintResult> {
    let mut found: Vec<(String, PathBuf)> = Vec::new();
    collect_rs(root, "", &mut found)?;
    found.sort_by(|a, b| a.0.cmp(&b.0));
    let mut files: Vec<(String, String)> = Vec::new();
    for (rel, path) in found {
        files.push((rel, fs::read_to_string(&path)?));
    }
    Ok(lint_files(&files))
}

fn collect_rs(dir: &Path, rel: &str, out: &mut Vec<(String, PathBuf)>) -> io::Result<()> {
    let mut entries: Vec<fs::DirEntry> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let name = e.file_name().to_string_lossy().into_owned();
        let sub = if rel.is_empty() { name.clone() } else { format!("{rel}/{name}") };
        if e.file_type()?.is_dir() {
            collect_rs(&e.path(), &sub, out)?;
        } else if name.ends_with(".rs") {
            out.push((sub, e.path()));
        }
    }
    Ok(())
}

/// Ruff-style text rendering: `error[CODE]: msg`, a `-->` locus line, and
/// the rule's one-line help. `prefix` (usually the scan root) is joined
/// onto each relative path so the locus is clickable from the invocation
/// directory.
pub fn render_text(diags: &[Diagnostic], prefix: &str) -> String {
    let mut out = String::new();
    for d in diags {
        let path = if prefix.is_empty() {
            d.path.clone()
        } else {
            format!("{}/{}", prefix.trim_end_matches('/'), d.path)
        };
        out.push_str(&format!("error[{}]: {}\n", d.code, d.message));
        out.push_str(&format!("  --> {}:{}:{}\n", path, d.line, d.col));
        if let Some(r) = rule(d.code) {
            out.push_str(&format!("  = help: {}\n", r.help));
        }
        out.push('\n');
    }
    out
}

/// Machine-readable rendering (one stable JSON object; no serde — the
/// shape is flat enough to emit by hand).
pub fn render_json(result: &LintResult, prefix: &str) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"files\": {},\n", result.files));
    out.push_str(&format!("  \"suppressed\": {},\n", result.suppressed));
    out.push_str(&format!("  \"count\": {},\n", result.diagnostics.len()));
    out.push_str("  \"diagnostics\": [");
    for (i, d) in result.diagnostics.iter().enumerate() {
        let path = if prefix.is_empty() {
            d.path.clone()
        } else {
            format!("{}/{}", prefix.trim_end_matches('/'), d.path)
        };
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"code\": \"{}\", \"path\": \"{}\", \"line\": {}, \"col\": {}, \"message\": \"{}\"}}",
            json_escape(d.code),
            json_escape(&path),
            d.line,
            d.col,
            json_escape(&d.message)
        ));
    }
    if !result.diagnostics.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
