//! The detlint rule set: determinism & wire-honesty invariants of the
//! fed3sfc tree, enforced at the source level.
//!
//! Every rule front-runs a *dynamic* invariant the test suite pins after
//! the fact (bit-identical trajectories across thread counts and session
//! modes, exact byte ledgers): the point of the static pass is to reject
//! the bug class before it can corrupt a golden run. See `RULES` for the
//! index and `Rule::explain` for the rationale per code.

use std::collections::BTreeMap;

use crate::lexer::{lex, Comment, Tok, TokKind};

/// One finding, positioned 1-based in a file relative to the scan root.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub code: &'static str,
    pub path: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

/// Result of linting a corpus.
pub struct LintResult {
    /// Surviving diagnostics, sorted by (path, line, col, code).
    pub diagnostics: Vec<Diagnostic>,
    /// Files scanned.
    pub files: usize,
    /// Findings suppressed by `// detlint: allow(...) -- reason` pragmas.
    pub suppressed: usize,
}

/// Static description of one rule.
pub struct Rule {
    pub code: &'static str,
    pub summary: &'static str,
    /// One-line remediation hint appended to each rendered diagnostic.
    pub help: &'static str,
    /// Long-form rationale for `detlint explain <code>`.
    pub explain: &'static str,
}

pub const RULES: &[Rule] = &[
    Rule {
        code: "DET000",
        summary: "detlint pragma hygiene: known rule, mandatory reason, no stale allows",
        help: "pragma syntax is `// detlint: allow(<RULE>) -- <reason>`; the reason is mandatory \
               and the pragma must suppress at least one finding",
        explain: "Suppressions are part of the audit surface: an `allow` without a reason is an \
                  unreviewable hole, and an `allow` that no longer matches a finding is drift. \
                  DET000 rejects malformed pragmas, unknown rule codes, missing reasons, and \
                  pragmas that suppress nothing. DET000 itself cannot be suppressed.",
    },
    Rule {
        code: "DET001",
        summary: "wall-clock read (`Instant::now` / `SystemTime`) outside allowlisted timing sites",
        help: "sim-path code takes time from `simnet::SimClock`; real stopwatches are confined to \
               `bench/`, `coordinator/metrics.rs`, and the wall_ms/eval_ms probes in \
               `coordinator/experiment.rs`",
        explain: "Every trajectory this repo reproduces runs on a virtual clock \
                  (`simnet::SimClock`): round timing, deadlines, and staleness are simulated so \
                  runs replay bit-for-bit. A wall-clock read on the sim path couples results to \
                  host speed and breaks the golden equivalences `session_test` pins dynamically. \
                  Allowed sites are diagnostics only: `bench/`, `coordinator/metrics.rs`, and the \
                  wall_ms/eval_ms stopwatch in `coordinator/experiment.rs` (which measure, but \
                  never steer, a run). Anything else needs a reasoned pragma.",
    },
    Rule {
        code: "DET002",
        summary: "`HashMap`/`HashSet` in `coordinator/`, `compress/`, or `simnet/`",
        help: "use `BTreeMap`/`Vec` so iteration order is defined; a keyed-lookup-only use may be \
               pragma'd with a reason",
        explain: "Aggregation walks its maps: client updates, ledgers, and event queues are \
                  folded in a defined order so `threads = N` stays bit-identical to `threads = 1` \
                  (`parallel_test`, `session_test`, `downlink_test`). `HashMap`/`HashSet` \
                  iteration order is randomized per process, so a single unordered walk in \
                  `coordinator/`, `compress/`, or `simnet/` silently breaks every golden \
                  trajectory. Keyed lookup without iteration (like the PJRT executable cache in \
                  `runtime/client.rs`, which lives outside the scanned dirs) is legal — \
                  inside the scanned dirs it takes a reasoned pragma.",
    },
    Rule {
        code: "DET003",
        summary: "ambient randomness (`thread_rng`, `from_entropy`, `rand::random`, stray `Rng::new`)",
        help: "all randomness descends from the experiment root via `Rng::split` with a \
               `util::rng::stream` tag; root construction is confined to the seed plumbing",
        explain: "Reproducibility rests on a single seeded root: every stochastic choice flows \
                  through `util::rng::Rng` streams split off `Rng::new(cfg.seed)`. Ambient \
                  entropy (`thread_rng`, `from_entropy`, `rand::random`) makes runs unrepeatable \
                  outright, and a stray `Rng::new(<constant>)` forks a second root whose draws \
                  silently decouple from the experiment seed. `Rng::new` is allowed only in \
                  `util/rng.rs`, `config/`, and the `testing/` prop harness (which mints case \
                  seeds deterministically); other construction sites (the experiment root, CLI \
                  tools, dataset synthesis) carry reasoned pragmas marking them as seed \
                  plumbing.",
    },
    Rule {
        code: "DET004",
        summary: "duplicate RNG stream tag across distinct `split(0x…)` call sites",
        help: "two streams sharing a tag draw correlated values; mint a fresh constant in \
               `util::rng::stream`",
        explain: "`Rng::split(tag)` derives a child stream purely from the parent state and the \
                  tag, so two *different* purposes splitting the same tag off the same root get \
                  the *same* stream — correlated draws that are almost impossible to spot \
                  dynamically. The repo's tags live as named constants in `util::rng::stream`; \
                  DET004 collects every integer-literal `split(...)` site plus the constant \
                  table itself and rejects any value that appears at more than one site. \
                  Deliberate sharing (one purpose, two call sites) should reference one named \
                  constant instead of repeating the literal.",
    },
    Rule {
        code: "DET005",
        summary: "`unsafe` block without a `// SAFETY:` comment",
        help: "state the invariant that makes the block sound on the line(s) directly above \
               (`clippy::undocumented_unsafe_blocks` is `deny` in rust/Cargo.toml)",
        explain: "The tree keeps `unsafe` rare (POD byte views in `runtime/bytes.rs`) and every \
                  block must carry the invariant that makes it sound, where the next reader can \
                  see it. DET005 mirrors `clippy::undocumented_unsafe_blocks` (deny'd in the \
                  manifest) so the check also runs without clippy, and the miri CI job \
                  sanitizes the same sites dynamically.",
    },
    Rule {
        code: "WIRE001",
        summary: "`wire_bytes` implementation without paired `serialize`/`deserialize`",
        help: "`wire_bytes` must price exactly the bytes `serialize` emits; implement both plus \
               `deserialize` on the same type and keep the round-trip property tests green",
        explain: "Traffic accounting is only honest if the priced payload actually materializes \
                  on a wire: for every envelope in `compress/` the contract is \
                  `serialize().len() == wire_bytes()` with a lossless `deserialize` round-trip \
                  (pinned by `prop_compressor_test` for `Payload` and `DeltaPayload`). A type \
                  that claims `wire_bytes` without both halves can report compression ratios \
                  nothing could ship. WIRE001 requires the trio to live on the same type in the \
                  same file.",
    },
];

/// Look up a rule by code.
pub fn rule(code: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.code == code)
}

/// Rule codes a pragma may allow (DET000 is the pragma police itself).
const ALLOWABLE: &[&str] = &["DET001", "DET002", "DET003", "DET004", "DET005", "WIRE001"];

/// A well-formed suppression pragma.
struct Pragma {
    line: u32,
    col: u32,
    /// The line this pragma covers: its own line for the trailing form,
    /// or — for the own-line form — the first *token* line after it
    /// (comment continuation lines in between don't count; 0 when no
    /// code follows).
    target: u32,
    codes: Vec<String>,
    used: bool,
}

/// An RNG stream tag occurrence (literal `split(...)` argument or a
/// `const NAME: u64 = …` entry of the stream-tag table).
struct TagSite {
    value: u128,
    display: String,
    line: u32,
    col: u32,
}

fn in_regions(regions: &[(u32, u32)], line: u32) -> bool {
    regions.iter().any(|&(a, b)| a <= line && line <= b)
}

/// Line ranges covered by `#[cfg(test)]` items. Test code may use ad-hoc
/// seeds, stopwatches, and scratch maps freely — the determinism
/// invariants concern the sim path, and the dynamic suites already run
/// the tests themselves.
fn test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut regions = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let is_cfg_test = toks[i].text == "#"
            && i + 6 < toks.len()
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")"
            && toks[i + 6].text == "]";
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        // Skip any further attributes between the cfg and the item.
        let mut j = i + 7;
        while j + 1 < toks.len() && toks[j].text == "#" && toks[j + 1].text == "[" {
            let mut depth = 0i32;
            j += 1;
            while j < toks.len() {
                if toks[j].text == "[" {
                    depth += 1;
                } else if toks[j].text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // Find the item's body; a `;` first means no body (e.g. `mod x;`).
        let mut open = None;
        while j < toks.len() {
            match toks[j].text.as_str() {
                ";" => break,
                "{" => {
                    open = Some(j);
                    break;
                }
                _ => j += 1,
            }
        }
        let Some(open) = open else {
            i = j.max(i + 7);
            continue;
        };
        let mut depth = 0i32;
        let mut k = open;
        let mut end_line = toks[toks.len() - 1].line;
        while k < toks.len() {
            if toks[k].text == "{" {
                depth += 1;
            } else if toks[k].text == "}" {
                depth -= 1;
                if depth == 0 {
                    end_line = toks[k].line;
                    break;
                }
            }
            k += 1;
        }
        regions.push((start_line, end_line));
        i = k + 1;
    }
    regions
}

/// Parse `// detlint: allow(<RULE>[, <RULE>]) -- <reason>` comments.
/// Returns the well-formed pragmas plus DET000 diagnostics for the
/// malformed ones.
fn parse_pragmas(
    rel: &str,
    toks: &[Tok],
    comments: &[Comment],
    regions: &[(u32, u32)],
) -> (Vec<Pragma>, Vec<Diagnostic>) {
    let mut pragmas = Vec::new();
    let mut diags = Vec::new();
    for c in comments {
        if in_regions(regions, c.line) {
            continue;
        }
        let body = c.text.trim_start_matches('/').trim_start_matches('*').trim();
        let Some(rest) = body.strip_prefix("detlint:") else { continue };
        let rest = rest.trim();
        let mut bad = |message: String| {
            diags.push(Diagnostic {
                code: "DET000",
                path: rel.to_string(),
                line: c.line,
                col: c.col,
                message,
            });
        };
        let Some(inner) = rest.strip_prefix("allow(") else {
            bad("malformed detlint pragma: expected `detlint: allow(<RULE>[, <RULE>]) -- <reason>`"
                .to_string());
            continue;
        };
        let Some(close) = inner.find(')') else {
            bad("malformed detlint pragma: unclosed `allow(`".to_string());
            continue;
        };
        let codes: Vec<String> = inner[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if codes.is_empty() {
            bad("detlint pragma allows no rule: name one of DET001–DET005, WIRE001".to_string());
            continue;
        }
        if let Some(unknown) = codes.iter().find(|code| !ALLOWABLE.contains(&code.as_str())) {
            bad(format!(
                "unknown rule `{unknown}` in detlint pragma (known: DET001–DET005, WIRE001)"
            ));
            continue;
        }
        let after = inner[close + 1..].trim_start();
        let reason = after.strip_prefix("--").map(str::trim).unwrap_or("");
        if reason.is_empty() {
            bad("detlint pragma without a reason: append ` -- <why this site is exempt>`"
                .to_string());
            continue;
        }
        let target = if c.own_line {
            toks.iter().map(|t| t.line).find(|&l| l > c.line).unwrap_or(0)
        } else {
            c.line
        };
        pragmas.push(Pragma { line: c.line, col: c.col, target, codes, used: false });
    }
    (pragmas, diags)
}

/// Parse an integer-literal token (`42`, `0x9A87_1710`, optionally
/// type-suffixed) into its value.
fn parse_int(text: &str) -> Option<u128> {
    let s: String = text.chars().filter(|&c| c != '_').collect();
    let s = s.to_ascii_lowercase();
    if let Some(hex) = s.strip_prefix("0x") {
        let digits: String = hex.chars().take_while(|c| c.is_ascii_hexdigit()).collect();
        if digits.is_empty() {
            return None;
        }
        u128::from_str_radix(&digits, 16).ok()
    } else {
        let digits: String = s.chars().take_while(|c| c.is_ascii_digit()).collect();
        if digits.is_empty() {
            return None;
        }
        digits.parse().ok()
    }
}

struct FileScan {
    findings: Vec<Diagnostic>,
    pragmas: Vec<Pragma>,
    pragma_errors: Vec<Diagnostic>,
    tags: Vec<TagSite>,
}

fn tok_is(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.text == text)
}

fn scan_file(rel: &str, src: &str) -> FileScan {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let regions = test_regions(toks);
    let (pragmas, pragma_errors) = parse_pragmas(rel, toks, &lexed.comments, &regions);

    let mut findings: Vec<Diagnostic> = Vec::new();
    let mut tags: Vec<TagSite> = Vec::new();
    let push = |code: &'static str, tok: &Tok, message: String, findings: &mut Vec<Diagnostic>| {
        findings.push(Diagnostic {
            code,
            path: rel.to_string(),
            line: tok.line,
            col: tok.col,
            message,
        });
    };

    // File-scope rule applicability.
    let det001_allowed = rel.starts_with("bench/")
        || rel == "coordinator/metrics.rs"
        || rel == "coordinator/experiment.rs";
    let det002_scope =
        rel.starts_with("coordinator/") || rel.starts_with("compress/") || rel.starts_with("simnet/");
    // `testing/` is the property-test harness: it mints case seeds
    // deterministically from the case index, i.e. it *is* seed plumbing.
    let det003_rng_new_allowed =
        rel == "util/rng.rs" || rel.starts_with("config/") || rel.starts_with("testing/");

    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident || in_regions(&regions, t.line) {
            continue;
        }
        let path_sep = |k: usize| tok_is(toks, k, ":") && tok_is(toks, k + 1, ":");

        // DET001 — wall-clock reads.
        if !det001_allowed {
            if t.text == "SystemTime" {
                push(
                    "DET001",
                    t,
                    "wall-clock read (`SystemTime`) outside an allowlisted timing site".to_string(),
                    &mut findings,
                );
            }
            if t.text == "Instant" && path_sep(i + 1) && tok_is(toks, i + 3, "now") {
                push(
                    "DET001",
                    t,
                    "wall-clock read (`Instant::now`) outside an allowlisted timing site"
                        .to_string(),
                    &mut findings,
                );
            }
        }

        // DET002 — unordered containers in deterministic aggregation code.
        if det002_scope && (t.text == "HashMap" || t.text == "HashSet") {
            push(
                "DET002",
                t,
                format!(
                    "`{}` in deterministic aggregation code (iteration order is unordered)",
                    t.text
                ),
                &mut findings,
            );
        }

        // DET003 — ambient randomness.
        if t.text == "thread_rng" || t.text == "from_entropy" {
            push(
                "DET003",
                t,
                format!("ambient randomness (`{}`) outside the seeded RNG plumbing", t.text),
                &mut findings,
            );
        }
        if t.text == "rand" && path_sep(i + 1) && tok_is(toks, i + 3, "random") {
            push(
                "DET003",
                t,
                "ambient randomness (`rand::random`) outside the seeded RNG plumbing".to_string(),
                &mut findings,
            );
        }
        if !det003_rng_new_allowed
            && t.text == "Rng"
            && path_sep(i + 1)
            && tok_is(toks, i + 3, "new")
        {
            push(
                "DET003",
                t,
                "root RNG construction (`Rng::new`) outside the config/seed plumbing".to_string(),
                &mut findings,
            );
        }

        // DET004 (collection) — literal `split(0x…)` tags.
        if t.text == "split" && tok_is(toks, i + 1, "(") {
            if let Some(arg) = toks.get(i + 2) {
                if arg.kind == TokKind::Int {
                    if let Some(value) = parse_int(&arg.text) {
                        tags.push(TagSite {
                            value,
                            display: arg.text.clone(),
                            line: arg.line,
                            col: arg.col,
                        });
                    }
                }
            }
        }

        // DET004 (collection) — the named stream-tag table itself.
        if rel == "util/rng.rs"
            && t.text == "const"
            && toks.get(i + 1).is_some_and(|t| t.kind == TokKind::Ident)
            && tok_is(toks, i + 2, ":")
            && tok_is(toks, i + 3, "u64")
            && tok_is(toks, i + 4, "=")
        {
            if let Some(lit) = toks.get(i + 5) {
                if lit.kind == TokKind::Int {
                    if let Some(value) = parse_int(&lit.text) {
                        tags.push(TagSite {
                            value,
                            display: lit.text.clone(),
                            line: lit.line,
                            col: lit.col,
                        });
                    }
                }
            }
        }

        // DET005 — undocumented unsafe blocks.
        if t.text == "unsafe" && tok_is(toks, i + 1, "{") {
            let documented = lexed.comments.iter().any(|c| {
                c.line + 4 >= t.line && c.line <= t.line && c.text.contains("SAFETY:")
            });
            if !documented {
                push(
                    "DET005",
                    t,
                    "`unsafe` block without a `// SAFETY:` comment".to_string(),
                    &mut findings,
                );
            }
        }
    }

    // WIRE001 — wire honesty in compress/.
    if rel.starts_with("compress/") {
        wire001(rel, toks, &regions, &mut findings);
    }

    FileScan { findings, pragmas, pragma_errors, tags }
}

/// Collect per-type method names out of `impl` blocks and require that any
/// type declaring `fn wire_bytes` also declares `serialize` and
/// `deserialize` (in any impl of that type in the same file).
fn wire001(rel: &str, toks: &[Tok], regions: &[(u32, u32)], findings: &mut Vec<Diagnostic>) {
    let mut methods: BTreeMap<String, Vec<String>> = BTreeMap::new();
    let mut wire_site: BTreeMap<String, (u32, u32)> = BTreeMap::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].kind == TokKind::Ident
            && toks[i].text == "impl"
            && !in_regions(regions, toks[i].line))
        {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        // Skip the generic-parameter list, if any.
        if tok_is(toks, j, "<") {
            let mut depth = 0i32;
            while j < toks.len() {
                if toks[j].text == "<" {
                    depth += 1;
                } else if toks[j].text == ">" {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // Header up to the opening brace; `impl Trait for Type` names the
        // type after `for`.
        let header_start = j;
        while j < toks.len() && toks[j].text != "{" && toks[j].text != ";" {
            j += 1;
        }
        if j >= toks.len() || toks[j].text != "{" {
            i = j;
            continue;
        }
        let header = &toks[header_start..j];
        let seg = match header.iter().position(|t| t.text == "for") {
            Some(pos) => &header[pos + 1..],
            None => header,
        };
        let ty = seg
            .iter()
            .find(|t| t.kind == TokKind::Ident && t.text != "dyn" && t.text != "mut")
            .map(|t| t.text.clone());
        // Walk the impl body; `fn` at depth 1 is a method of this impl.
        let mut depth = 0i32;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                "fn" if depth == 1 => {
                    if let (Some(ty), Some(name)) = (ty.as_ref(), toks.get(j + 1)) {
                        methods.entry(ty.clone()).or_default().push(name.text.clone());
                        if name.text == "wire_bytes" {
                            wire_site.entry(ty.clone()).or_insert((name.line, name.col));
                        }
                    }
                }
                _ => {}
            }
            j += 1;
        }
        i = j + 1;
    }
    for (ty, &(line, col)) in &wire_site {
        let ms = &methods[ty];
        let paired =
            ms.iter().any(|m| m == "serialize") && ms.iter().any(|m| m == "deserialize");
        if !paired {
            findings.push(Diagnostic {
                code: "WIRE001",
                path: rel.to_string(),
                line,
                col,
                message: format!(
                    "`{ty}::wire_bytes` lacks a paired `serialize`/`deserialize` on the same type"
                ),
            });
        }
    }
}

/// Lint a corpus of (path-relative-to-root, source) files. DET004 is
/// cross-file, so the whole corpus goes through one call.
pub fn lint_files(files: &[(String, String)]) -> LintResult {
    let mut scans: Vec<FileScan> = Vec::new();
    for (rel, src) in files {
        scans.push(scan_file(rel, src));
    }

    // DET004 — duplicate tags across the whole corpus.
    let mut by_value: BTreeMap<u128, Vec<(usize, usize)>> = BTreeMap::new();
    for (s_idx, scan) in scans.iter().enumerate() {
        for (t_idx, tag) in scan.tags.iter().enumerate() {
            by_value.entry(tag.value).or_default().push((s_idx, t_idx));
        }
    }
    let mut det004: Vec<(usize, Diagnostic)> = Vec::new();
    for sites in by_value.values() {
        if sites.len() < 2 {
            continue;
        }
        for &(s_idx, t_idx) in sites {
            let site = &scans[s_idx].tags[t_idx];
            let others: Vec<String> = sites
                .iter()
                .filter(|&&(s, t)| (s, t) != (s_idx, t_idx))
                .map(|&(s, t)| {
                    let o = &scans[s].tags[t];
                    format!("{}:{}", files[s].0, o.line)
                })
                .collect();
            det004.push((
                s_idx,
                Diagnostic {
                    code: "DET004",
                    path: files[s_idx].0.clone(),
                    line: site.line,
                    col: site.col,
                    message: format!(
                        "RNG stream tag `{}` is also used at {}",
                        site.display,
                        others.join(", ")
                    ),
                },
            ));
        }
    }
    for (s_idx, d) in det004 {
        scans[s_idx].findings.push(d);
    }

    // Suppression: a pragma covers its own line (trailing form) or the
    // next line (own-line form).
    let mut suppressed = 0usize;
    let mut diagnostics: Vec<Diagnostic> = Vec::new();
    for (s_idx, scan) in scans.iter_mut().enumerate() {
        for f in scan.findings.drain(..) {
            let mut hit = false;
            for p in scan.pragmas.iter_mut() {
                if p.target == f.line && p.codes.iter().any(|c| c == f.code) {
                    p.used = true;
                    hit = true;
                }
            }
            if hit {
                suppressed += 1;
            } else {
                diagnostics.push(f);
            }
        }
        diagnostics.extend(scan.pragma_errors.drain(..));
        for p in &scan.pragmas {
            if !p.used {
                diagnostics.push(Diagnostic {
                    code: "DET000",
                    path: files[s_idx].0.clone(),
                    line: p.line,
                    col: p.col,
                    message: "detlint pragma suppresses nothing (stale allow?)".to_string(),
                });
            }
        }
    }

    diagnostics.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.col, a.code).cmp(&(b.path.as_str(), b.line, b.col, b.code))
    });
    LintResult { diagnostics, files: files.len(), suppressed }
}
