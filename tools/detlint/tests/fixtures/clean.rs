// Fixture: idiomatic deterministic code (linted as coordinator/clean.rs).
// Ordered containers, a named stream tag, and test-only code that is free
// to use stopwatches and scratch hash maps — zero diagnostics expected.
use std::collections::BTreeMap;

use crate::util::rng::{stream, Rng};

pub fn fold_updates(updates: &BTreeMap<u32, f32>) -> f32 {
    updates.values().sum()
}

pub fn schedule_stream(root: &Rng) -> Rng {
    root.split(stream::SCHEDULE)
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use std::time::Instant;

    #[test]
    fn tests_may_use_wall_clocks_and_hash_maps() {
        let t0 = Instant::now();
        let mut scratch = HashMap::new();
        scratch.insert(1u32, t0.elapsed().as_secs_f64());
        assert_eq!(scratch.len(), 1);
    }
}
