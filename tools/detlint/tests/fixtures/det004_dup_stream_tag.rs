// Fixture: two purposes splitting the same literal tag (linted as
// coordinator/warmup.rs).
use crate::util::rng::Rng;

pub fn two_streams(root: &Rng) -> (Rng, Rng) {
    let warmup = root.split(0xD00D_F00D);
    let cooldown = root.split(0xD00D_F00D);
    (warmup, cooldown)
}
