// Fixture: wall-clock reads on the sim path (linted as simnet/latency.rs).
use std::time::{Instant, SystemTime};

pub fn wall_probe() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn clock_entropy() -> SystemTime {
    SystemTime::now()
}
