// Fixture: a compress/ type pricing bytes it cannot ship (linted as
// compress/sketch.rs). `Honest` carries the full trio and stays clean.
pub struct Sketch {
    pub bits: Vec<u8>,
}

impl Sketch {
    pub fn wire_bytes(&self) -> usize {
        8 + self.bits.len()
    }
}

pub struct Honest;

impl Honest {
    pub fn wire_bytes(&self) -> usize {
        8
    }

    pub fn serialize(&self) -> Vec<u8> {
        vec![0; 8]
    }

    pub fn deserialize(_bytes: &[u8]) -> Honest {
        Honest
    }
}
