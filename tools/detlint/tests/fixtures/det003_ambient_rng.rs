// Fixture: ambient randomness (linted as data/sampler.rs).
use crate::util::rng::Rng;

pub fn jitter() -> u64 {
    let mut rng = Rng::new(0xBAD_5EED);
    rng.next_u64() ^ rand::random::<u64>()
}

pub fn ambient() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}
