// Fixture: unordered container in aggregation code (linted as
// coordinator/policy.rs — the seeded-violation example from the issue).
use std::collections::HashMap;

pub fn tally(xs: &[(u32, f32)]) -> f32 {
    let mut by_client: HashMap<u32, f32> = HashMap::new();
    for (id, v) in xs {
        *by_client.entry(*id).or_insert(0.0) += v;
    }
    by_client.values().sum()
}
