// Fixture: pragma hygiene failures (linted as simnet/sloppy.rs). The
// un-reasoned pragma does NOT suppress its finding, the unknown rule and
// the stale allow are DET000s of their own.
use std::time::Instant;

pub fn sloppy_ms() -> f64 {
    let t0 = Instant::now(); // detlint: allow(DET001)
    // detlint: allow(DET999) -- no such rule
    let t1 = Instant::now();
    (t1 - t0).as_secs_f64() * 1e3
}

// detlint: allow(DET002) -- nothing here uses a hash map
pub fn stale() -> u32 {
    7
}
