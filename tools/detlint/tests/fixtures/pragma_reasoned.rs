// Fixture: both pragma forms with reasons (linted as simnet/probe.rs).
// Every finding is suppressed; zero diagnostics, two suppressions.
use std::time::Instant;

pub fn probe_ms() -> f64 {
    // detlint: allow(DET001) -- debug probe, printed only, and the
    // own-line form may flow over comment continuation lines like this.
    let t0 = Instant::now();
    let t1 = Instant::now(); // detlint: allow(DET001) -- trailing form demo
    (t1 - t0).as_secs_f64() * 1e3
}
