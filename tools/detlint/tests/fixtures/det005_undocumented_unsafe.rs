// Fixture: one documented and one undocumented unsafe block (linted as
// runtime/view.rs). Only the second may be flagged.
pub fn documented(data: &[u32]) -> &[u8] {
    // SAFETY: u32 is POD; the span is the exact byte length of a live slice.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, 4 * data.len()) }
}

pub fn undocumented(data: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, 4 * data.len()) }
}
