//! Golden tests: each rule demonstrated by a minimal fixture whose
//! rendered diagnostic text must match byte-for-byte (ruff-style
//! snapshots, hand-pinned). Fixtures live under `tests/fixtures/` and are
//! linted under *virtual* paths because the rules are path-sensitive.

use detlint::{lint_files, lint_source, render_text};

/// Lint `src` as if it sat at `vpath` under the scan root and compare the
/// rendered text + suppression count against the pinned snapshot.
fn check(vpath: &str, src: &str, expected_suppressed: usize, expected: &str) {
    let result = lint_source(vpath, src);
    assert_eq!(
        result.suppressed, expected_suppressed,
        "suppression count for {vpath}"
    );
    let text = render_text(&result.diagnostics, "");
    assert_eq!(text, expected, "diagnostic text for {vpath}");
}

#[test]
fn det001_wall_clock_reads() {
    check(
        "simnet/latency.rs",
        include_str!("fixtures/det001_wallclock.rs"),
        0,
        r"error[DET001]: wall-clock read (`SystemTime`) outside an allowlisted timing site
  --> simnet/latency.rs:2:26
  = help: sim-path code takes time from `simnet::SimClock`; real stopwatches are confined to `bench/`, `coordinator/metrics.rs`, and the wall_ms/eval_ms probes in `coordinator/experiment.rs`

error[DET001]: wall-clock read (`Instant::now`) outside an allowlisted timing site
  --> simnet/latency.rs:5:14
  = help: sim-path code takes time from `simnet::SimClock`; real stopwatches are confined to `bench/`, `coordinator/metrics.rs`, and the wall_ms/eval_ms probes in `coordinator/experiment.rs`

error[DET001]: wall-clock read (`SystemTime`) outside an allowlisted timing site
  --> simnet/latency.rs:9:27
  = help: sim-path code takes time from `simnet::SimClock`; real stopwatches are confined to `bench/`, `coordinator/metrics.rs`, and the wall_ms/eval_ms probes in `coordinator/experiment.rs`

error[DET001]: wall-clock read (`SystemTime`) outside an allowlisted timing site
  --> simnet/latency.rs:10:5
  = help: sim-path code takes time from `simnet::SimClock`; real stopwatches are confined to `bench/`, `coordinator/metrics.rs`, and the wall_ms/eval_ms probes in `coordinator/experiment.rs`

",
    );
}

#[test]
fn det002_hash_containers_in_aggregation_code() {
    check(
        "coordinator/policy.rs",
        include_str!("fixtures/det002_hashmap.rs"),
        0,
        r"error[DET002]: `HashMap` in deterministic aggregation code (iteration order is unordered)
  --> coordinator/policy.rs:3:23
  = help: use `BTreeMap`/`Vec` so iteration order is defined; a keyed-lookup-only use may be pragma'd with a reason

error[DET002]: `HashMap` in deterministic aggregation code (iteration order is unordered)
  --> coordinator/policy.rs:6:24
  = help: use `BTreeMap`/`Vec` so iteration order is defined; a keyed-lookup-only use may be pragma'd with a reason

error[DET002]: `HashMap` in deterministic aggregation code (iteration order is unordered)
  --> coordinator/policy.rs:6:44
  = help: use `BTreeMap`/`Vec` so iteration order is defined; a keyed-lookup-only use may be pragma'd with a reason

",
    );
}

#[test]
fn det002_is_scoped_to_deterministic_dirs() {
    // The identical source under runtime/ is legal (e.g. the PJRT
    // executable cache does keyed lookup there).
    let result = lint_source("runtime/cache.rs", include_str!("fixtures/det002_hashmap.rs"));
    assert!(result.diagnostics.is_empty(), "{:?}", result.diagnostics);
}

#[test]
fn det003_ambient_randomness() {
    check(
        "data/sampler.rs",
        include_str!("fixtures/det003_ambient_rng.rs"),
        0,
        r"error[DET003]: root RNG construction (`Rng::new`) outside the config/seed plumbing
  --> data/sampler.rs:5:19
  = help: all randomness descends from the experiment root via `Rng::split` with a `util::rng::stream` tag; root construction is confined to the seed plumbing

error[DET003]: ambient randomness (`rand::random`) outside the seeded RNG plumbing
  --> data/sampler.rs:6:22
  = help: all randomness descends from the experiment root via `Rng::split` with a `util::rng::stream` tag; root construction is confined to the seed plumbing

error[DET003]: ambient randomness (`thread_rng`) outside the seeded RNG plumbing
  --> data/sampler.rs:10:25
  = help: all randomness descends from the experiment root via `Rng::split` with a `util::rng::stream` tag; root construction is confined to the seed plumbing

",
    );
}

#[test]
fn det004_duplicate_stream_tags_single_file() {
    check(
        "coordinator/warmup.rs",
        include_str!("fixtures/det004_dup_stream_tag.rs"),
        0,
        r"error[DET004]: RNG stream tag `0xD00D_F00D` is also used at coordinator/warmup.rs:7
  --> coordinator/warmup.rs:6:29
  = help: two streams sharing a tag draw correlated values; mint a fresh constant in `util::rng::stream`

error[DET004]: RNG stream tag `0xD00D_F00D` is also used at coordinator/warmup.rs:6
  --> coordinator/warmup.rs:7:31
  = help: two streams sharing a tag draw correlated values; mint a fresh constant in `util::rng::stream`

",
    );
}

#[test]
fn det004_duplicate_stream_tags_cross_file() {
    // The same value written two ways (hex with separators vs decimal) in
    // two different files is still one tag — the scan is corpus-wide and
    // compares numeric values, not spellings.
    let a = "pub fn s(r: &crate::util::rng::Rng) -> crate::util::rng::Rng { r.split(0x2A) }\n";
    let b = "pub fn t(r: &crate::util::rng::Rng) -> crate::util::rng::Rng { r.split(42) }\n";
    let result = lint_files(&[
        ("coordinator/a.rs".to_string(), a.to_string()),
        ("coordinator/b.rs".to_string(), b.to_string()),
    ]);
    let codes: Vec<&str> = result.diagnostics.iter().map(|d| d.code).collect();
    assert_eq!(codes, ["DET004", "DET004"], "{:?}", result.diagnostics);
    assert!(result.diagnostics[0].message.contains("coordinator/b.rs:1"));
    assert!(result.diagnostics[1].message.contains("coordinator/a.rs:1"));
}

#[test]
fn det005_undocumented_unsafe() {
    check(
        "runtime/view.rs",
        include_str!("fixtures/det005_undocumented_unsafe.rs"),
        0,
        r"error[DET005]: `unsafe` block without a `// SAFETY:` comment
  --> runtime/view.rs:9:5
  = help: state the invariant that makes the block sound on the line(s) directly above (`clippy::undocumented_unsafe_blocks` is `deny` in rust/Cargo.toml)

",
    );
}

#[test]
fn wire001_unpaired_wire_bytes() {
    check(
        "compress/sketch.rs",
        include_str!("fixtures/wire001_wire_bytes_unpaired.rs"),
        0,
        r"error[WIRE001]: `Sketch::wire_bytes` lacks a paired `serialize`/`deserialize` on the same type
  --> compress/sketch.rs:8:12
  = help: `wire_bytes` must price exactly the bytes `serialize` emits; implement both plus `deserialize` on the same type and keep the round-trip property tests green

",
    );
}

#[test]
fn wire001_only_applies_under_compress() {
    let result = lint_source(
        "coordinator/sketch.rs",
        include_str!("fixtures/wire001_wire_bytes_unpaired.rs"),
    );
    assert!(result.diagnostics.is_empty(), "{:?}", result.diagnostics);
}

#[test]
fn clean_fixture_is_clean() {
    // Ordered containers, named stream tags, and `#[cfg(test)]` regions
    // (where stopwatches and hash maps are legal) produce nothing.
    check("coordinator/clean.rs", include_str!("fixtures/clean.rs"), 0, "");
}

#[test]
fn reasoned_pragmas_suppress_both_forms() {
    check("simnet/probe.rs", include_str!("fixtures/pragma_reasoned.rs"), 2, "");
}

#[test]
fn pragma_hygiene_failures_are_det000() {
    check(
        "simnet/sloppy.rs",
        include_str!("fixtures/pragma_unreasoned.rs"),
        0,
        r"error[DET001]: wall-clock read (`Instant::now`) outside an allowlisted timing site
  --> simnet/sloppy.rs:7:14
  = help: sim-path code takes time from `simnet::SimClock`; real stopwatches are confined to `bench/`, `coordinator/metrics.rs`, and the wall_ms/eval_ms probes in `coordinator/experiment.rs`

error[DET000]: detlint pragma without a reason: append ` -- <why this site is exempt>`
  --> simnet/sloppy.rs:7:30
  = help: pragma syntax is `// detlint: allow(<RULE>) -- <reason>`; the reason is mandatory and the pragma must suppress at least one finding

error[DET000]: unknown rule `DET999` in detlint pragma (known: DET001–DET005, WIRE001)
  --> simnet/sloppy.rs:8:5
  = help: pragma syntax is `// detlint: allow(<RULE>) -- <reason>`; the reason is mandatory and the pragma must suppress at least one finding

error[DET001]: wall-clock read (`Instant::now`) outside an allowlisted timing site
  --> simnet/sloppy.rs:9:14
  = help: sim-path code takes time from `simnet::SimClock`; real stopwatches are confined to `bench/`, `coordinator/metrics.rs`, and the wall_ms/eval_ms probes in `coordinator/experiment.rs`

error[DET000]: detlint pragma suppresses nothing (stale allow?)
  --> simnet/sloppy.rs:13:1
  = help: pragma syntax is `// detlint: allow(<RULE>) -- <reason>`; the reason is mandatory and the pragma must suppress at least one finding

",
    );
}

#[test]
fn every_rule_has_registry_metadata() {
    for code in ["DET000", "DET001", "DET002", "DET003", "DET004", "DET005", "WIRE001"] {
        let rule = detlint::rule(code).unwrap_or_else(|| panic!("missing rule {code}"));
        assert!(!rule.summary.is_empty());
        assert!(!rule.help.is_empty());
        assert!(!rule.explain.is_empty());
    }
}
